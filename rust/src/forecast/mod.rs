//! Short-term demand forecasting (Sec. VI): providers for the prediction
//! window `d̂_{t+1..t+w}` consumed by `A^w_z`.
//!
//! The paper assumes reliable short-term predictions; these forecasters let
//! the examples and ablation benches quantify how much of the Fig. 6/7
//! gain survives *imperfect* predictions. The AR(k) model mirrors the L2
//! JAX forecaster — `fit_ar` produces the coefficients that
//! `python/compile/model.py` applies in the AOT artifact, and the
//! coordinator can run either implementation (bit-identical math).

use std::collections::VecDeque;

/// A streaming demand forecaster.
pub trait Forecaster: Send {
    fn name(&self) -> String;
    /// Observe the next actual demand.
    fn observe(&mut self, demand: u32);
    /// Predict the next `w` demands.
    fn predict(&self, w: usize) -> Vec<u32>;
}

/// Predicts the last observed value forever.
#[derive(Debug, Clone, Default)]
pub struct LastValue {
    last: u32,
}

impl Forecaster for LastValue {
    fn name(&self) -> String {
        "last-value".into()
    }

    fn observe(&mut self, demand: u32) {
        self.last = demand;
    }

    fn predict(&self, w: usize) -> Vec<u32> {
        vec![self.last; w]
    }
}

/// Moving average over the last `k` observations.
#[derive(Debug, Clone)]
pub struct MovingAverage {
    k: usize,
    buf: VecDeque<u32>,
    sum: u64,
}

impl MovingAverage {
    pub fn new(k: usize) -> MovingAverage {
        assert!(k >= 1);
        MovingAverage { k, buf: VecDeque::new(), sum: 0 }
    }
}

impl Forecaster for MovingAverage {
    fn name(&self) -> String {
        format!("moving-average({})", self.k)
    }

    fn observe(&mut self, demand: u32) {
        self.buf.push_back(demand);
        self.sum += demand as u64;
        if self.buf.len() > self.k {
            self.sum -= self.buf.pop_front().unwrap() as u64;
        }
    }

    fn predict(&self, w: usize) -> Vec<u32> {
        let avg = if self.buf.is_empty() {
            0
        } else {
            ((self.sum as f64 / self.buf.len() as f64).round()) as u32
        };
        vec![avg; w]
    }
}

/// Seasonal-naive: predict the value one season (e.g., one day) back.
#[derive(Debug, Clone)]
pub struct SeasonalNaive {
    period: usize,
    buf: VecDeque<u32>,
}

impl SeasonalNaive {
    pub fn new(period: usize) -> SeasonalNaive {
        assert!(period >= 1);
        SeasonalNaive { period, buf: VecDeque::new() }
    }
}

impl Forecaster for SeasonalNaive {
    fn name(&self) -> String {
        format!("seasonal-naive({})", self.period)
    }

    fn observe(&mut self, demand: u32) {
        self.buf.push_back(demand);
        if self.buf.len() > self.period {
            self.buf.pop_front();
        }
    }

    fn predict(&self, w: usize) -> Vec<u32> {
        if self.buf.is_empty() {
            return vec![0; w];
        }
        (0..w)
            .map(|i| {
                // value `period` slots before t+1+i
                let idx = (self.buf.len() + i) % self.period.min(self.buf.len());
                self.buf[idx.min(self.buf.len() - 1)]
            })
            .collect()
    }
}

/// Fit AR(k) coefficients (with intercept) on a demand history by least
/// squares: `d_t ≈ c + Σ_j a_j · d_{t−j}`. Returns `[c, a_1, …, a_k]`.
/// Solved via normal equations + Gaussian elimination with partial
/// pivoting (k is small).
pub fn fit_ar(history: &[u32], k: usize) -> Vec<f64> {
    assert!(k >= 1);
    let n = history.len();
    if n <= k + 1 {
        // not enough data: fall back to predicting the mean
        let mean = if n == 0 {
            0.0
        } else {
            history.iter().map(|&x| x as f64).sum::<f64>() / n as f64
        };
        let mut c = vec![0.0; k + 1];
        c[0] = mean;
        return c;
    }
    let dim = k + 1;
    // X^T X and X^T y accumulated streaming
    let mut xtx = vec![vec![0.0f64; dim]; dim];
    let mut xty = vec![0.0f64; dim];
    let mut row = vec![0.0f64; dim];
    for t in k..n {
        row[0] = 1.0;
        for j in 1..=k {
            row[j] = history[t - j] as f64;
        }
        let y = history[t] as f64;
        for i in 0..dim {
            xty[i] += row[i] * y;
            for j in 0..dim {
                xtx[i][j] += row[i] * row[j];
            }
        }
    }
    // ridge regularization keeps degenerate (constant) histories solvable
    for (i, r) in xtx.iter_mut().enumerate() {
        r[i] += 1e-6;
    }
    solve_linear(xtx, xty)
}

/// Gaussian elimination with partial pivoting.
fn solve_linear(mut a: Vec<Vec<f64>>, mut b: Vec<f64>) -> Vec<f64> {
    let n = b.len();
    for col in 0..n {
        // pivot
        let pivot = (col..n)
            .max_by(|&i, &j| a[i][col].abs().partial_cmp(&a[j][col].abs()).unwrap())
            .unwrap();
        a.swap(col, pivot);
        b.swap(col, pivot);
        let diag = a[col][col];
        if diag.abs() < 1e-12 {
            continue; // singular direction; leave coefficient at 0
        }
        for r in col + 1..n {
            let f = a[r][col] / diag;
            for c in col..n {
                a[r][c] -= f * a[col][c];
            }
            b[r] -= f * b[col];
        }
    }
    let mut x = vec![0.0; n];
    for col in (0..n).rev() {
        let mut acc = b[col];
        for c in col + 1..n {
            acc -= a[col][c] * x[c];
        }
        x[col] = if a[col][col].abs() < 1e-12 { 0.0 } else { acc / a[col][col] };
    }
    x
}

/// Streaming AR(k) forecaster: refits every `refit_every` observations on a
/// rolling history window.
pub struct ArForecaster {
    k: usize,
    refit_every: usize,
    max_history: usize,
    history: VecDeque<u32>,
    coef: Vec<f64>,
    since_fit: usize,
}

impl ArForecaster {
    pub fn new(k: usize, refit_every: usize, max_history: usize) -> ArForecaster {
        assert!(max_history > k + 1);
        ArForecaster {
            k,
            refit_every,
            max_history,
            history: VecDeque::new(),
            coef: vec![0.0; k + 1],
            since_fit: 0,
        }
    }

    pub fn coefficients(&self) -> &[f64] {
        &self.coef
    }

    /// Iterated multi-step prediction with the current coefficients —
    /// mirrors the L2 `ar_forecast` graph exactly.
    pub fn predict_f64(&self, w: usize) -> Vec<f64> {
        let mut out = Vec::with_capacity(w);
        let mut scratch = Vec::new();
        self.predict_f64_into(w, &mut out, &mut scratch);
        out
    }

    /// Allocation-free variant for hot paths (PERF.md §Policy hot path):
    /// the AR iteration only ever consults the last `k` values, so we keep
    /// a k-sized rolling scratch instead of copying the whole history.
    pub fn predict_f64_into(&self, w: usize, out: &mut Vec<f64>, scratch: &mut Vec<f64>) {
        out.clear();
        scratch.clear();
        let n = self.history.len();
        for i in n.saturating_sub(self.k)..n {
            scratch.push(self.history[i] as f64);
        }
        // scratch holds the last <=k values, oldest first; index from the end
        for _ in 0..w {
            let m = scratch.len();
            let mut y = self.coef[0];
            for j in 1..=self.k {
                let v = if m >= j { scratch[m - j] } else { 0.0 };
                y += self.coef[j] * v;
            }
            let y = y.max(0.0);
            out.push(y);
            // slide the k-window: drop the oldest once we exceed k entries
            scratch.push(y);
            if scratch.len() > self.k {
                scratch.remove(0);
            }
        }
    }
}

impl crate::algos::Reset for ArForecaster {
    fn reset(&mut self) {
        self.history.clear();
        self.coef.iter_mut().for_each(|c| *c = 0.0);
        self.since_fit = 0;
    }
}

impl crate::algos::SaveState for ArForecaster {
    /// Wire: history (usize count + one u32 per observation), coefficients
    /// (usize count + f64 bits — count must equal `k + 1`), `since_fit`.
    /// Only dynamic state travels; `k`/`refit_every`/`max_history` are
    /// constructor parameters and cross-checked on restore.
    fn save_state(&self, w: &mut crate::util::state::StateWriter) {
        w.usize(self.history.len());
        for &d in &self.history {
            w.u32(d);
        }
        w.usize(self.coef.len());
        for &c in &self.coef {
            w.f64_bits(c);
        }
        w.usize(self.since_fit);
    }

    fn restore_state(
        &mut self,
        r: &mut crate::util::state::StateReader<'_>,
    ) -> anyhow::Result<()> {
        let n = r.seq_len(4)?;
        anyhow::ensure!(
            n <= self.max_history,
            "forecaster state: history length {n} exceeds max_history {}",
            self.max_history
        );
        self.history.clear();
        for _ in 0..n {
            self.history.push_back(r.u32()?);
        }
        let m = r.seq_len(8)?;
        anyhow::ensure!(
            m == self.coef.len(),
            "forecaster state: {m} coefficients, expected k+1={}",
            self.coef.len()
        );
        for c in self.coef.iter_mut() {
            *c = r.f64_bits()?;
        }
        self.since_fit = r.usize()?;
        Ok(())
    }
}

impl Forecaster for ArForecaster {
    fn name(&self) -> String {
        format!("ar({})", self.k)
    }

    fn observe(&mut self, demand: u32) {
        self.history.push_back(demand);
        if self.history.len() > self.max_history {
            self.history.pop_front();
        }
        self.since_fit += 1;
        if self.since_fit >= self.refit_every || self.coef.iter().all(|&c| c == 0.0) {
            let hist: Vec<u32> = self.history.iter().copied().collect();
            self.coef = fit_ar(&hist, self.k);
            self.since_fit = 0;
        }
    }

    fn predict(&self, w: usize) -> Vec<u32> {
        self.predict_f64(w).into_iter().map(|y| y.round().max(0.0) as u32).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn last_value_predicts_last() {
        let mut f = LastValue::default();
        f.observe(3);
        f.observe(7);
        assert_eq!(f.predict(3), vec![7, 7, 7]);
    }

    #[test]
    fn moving_average_windows() {
        let mut f = MovingAverage::new(2);
        f.observe(2);
        f.observe(4);
        f.observe(6);
        assert_eq!(f.predict(1), vec![5]); // mean(4,6)
    }

    #[test]
    fn seasonal_naive_repeats_cycle() {
        let mut f = SeasonalNaive::new(3);
        for d in [1, 2, 3] {
            f.observe(d);
        }
        assert_eq!(f.predict(3), vec![1, 2, 3]);
    }

    #[test]
    fn ar_fit_recovers_linear_recurrence() {
        // d_t = 0.5 d_{t-1} + 10 (fixed point 20)
        let mut h = vec![0u32];
        for _ in 0..200 {
            let prev = *h.last().unwrap() as f64;
            h.push((0.5 * prev + 10.0).round() as u32);
        }
        let coef = fit_ar(&h, 1);
        // rounding noise is tiny once the series settles, so expect
        // approximately [10, 0.5] -- but the series converges to constant 20,
        // making c + a*20 = 20 the identifiable constraint. Verify the
        // one-step prediction instead of raw coefficients.
        let pred = coef[0] + coef[1] * 20.0;
        assert!((pred - 20.0).abs() < 0.5, "coef={coef:?} pred={pred}");
    }

    #[test]
    fn ar_fit_on_ramp_extrapolates_upward() {
        let h: Vec<u32> = (0..100).collect();
        let coef = fit_ar(&h, 2);
        // next value should be ~100
        let pred = coef[0] + coef[1] * 99.0 + coef[2] * 98.0;
        assert!((pred - 100.0).abs() < 2.0, "coef={coef:?} pred={pred}");
    }

    #[test]
    fn ar_forecaster_streaming() {
        let mut f = ArForecaster::new(2, 10, 500);
        for i in 0..100u32 {
            f.observe(i % 10);
        }
        let p = f.predict(5);
        assert_eq!(p.len(), 5);
        // predictions stay in a sane range
        assert!(p.iter().all(|&x| x <= 20));
    }

    #[test]
    fn ar_fit_short_history_falls_back_to_mean() {
        let coef = fit_ar(&[4, 6], 3);
        assert!((coef[0] - 5.0).abs() < 1e-9);
        assert!(coef[1..].iter().all(|&c| c == 0.0));
    }

    #[test]
    fn ar_fit_constant_history_is_stable() {
        let h = vec![5u32; 50];
        let coef = fit_ar(&h, 3);
        let pred = coef[0] + coef[1..].iter().sum::<f64>() * 5.0;
        assert!((pred - 5.0).abs() < 0.1, "coef={coef:?} pred={pred}");
    }

    #[test]
    fn solve_linear_identity() {
        let a = vec![vec![1.0, 0.0], vec![0.0, 1.0]];
        let x = solve_linear(a, vec![3.0, -2.0]);
        assert!((x[0] - 3.0).abs() < 1e-12 && (x[1] + 2.0).abs() < 1e-12);
    }
}
