//! Minimal, API-compatible subset of the `anyhow` crate for fully offline
//! builds (the real crate is not in the vendor set). Implements exactly the
//! surface this repository uses:
//!
//! * [`Error`] — an opaque error carrying a context chain,
//! * [`Result<T>`] — `Result<T, Error>` alias,
//! * [`anyhow!`], [`bail!`], [`ensure!`] — formatting constructors,
//! * [`Context`] — `.context(..)` / `.with_context(..)` on `Result` and
//!   `Option`,
//! * blanket `From<E: std::error::Error>` so `?` converts automatically.
//!
//! Like the real crate, `Error` deliberately does **not** implement
//! `std::error::Error` (that is what makes the blanket `From` coherent).
//! `{:#}` formatting prints the whole context chain, `{}` the outermost
//! message, matching anyhow's behaviour closely enough for logs and tests.

use std::fmt;

/// An error with a chain of context messages (outermost first).
pub struct Error {
    chain: Vec<String>,
}

/// `Result<T, anyhow::Error>`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Construct from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> + '_ {
        self.chain.iter().map(|s| s.as_str())
    }

    /// Outermost message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` — the whole chain, colon-separated (anyhow's format).
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(|s| s.as_str()).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(|s| s.as_str()).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(err: E) -> Error {
        Error::msg(err.to_string())
    }
}

/// Attach context to errors (and `None`s), as in the real crate.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E> Context<T> for std::result::Result<T, E>
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| Error::msg(e.to_string()).context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| Error::msg(e.to_string()).context(f()))
    }
}

impl<T> Context<T> for std::result::Result<T, Error> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with a formatted [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            // io::Error converts through the blanket From impl
            std::fs::metadata("/nonexistent/cloudreserve-anyhow-shim-test")?;
            Ok(())
        }
        assert!(inner().is_err());
    }

    #[test]
    fn context_chain_formats() {
        let e: Result<(), std::io::Error> = Err(io_err());
        let e = e.with_context(|| "read manifest.json — run `make artifacts` first").unwrap_err();
        assert_eq!(format!("{e}"), "read manifest.json — run `make artifacts` first");
        let full = format!("{e:#}");
        assert!(full.contains("make artifacts") && full.contains("missing"), "{full}");
    }

    #[test]
    fn macros_work() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 5 {
                bail!("five is right out");
            }
            Ok(x)
        }
        assert!(f(3).is_ok());
        assert!(f(5).is_err());
        assert!(f(11).is_err());
        let e = anyhow!("code {}", 42);
        assert_eq!(e.to_string(), "code 42");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("empty").unwrap_err();
        assert_eq!(e.to_string(), "empty");
    }
}
