//! Offline stub of the `xla` crate (PJRT bindings).
//!
//! The real crate wraps `xla_extension` (a multi-GB native dependency) and
//! is unavailable in the offline vendor set. This stub mirrors the exact
//! type/method surface `cloudreserve::runtime` uses so the crate builds and
//! tests run everywhere; every entry point returns a descriptive [`Error`]
//! at runtime. The artifact-backed analytics path degrades gracefully: the
//! runtime loader surfaces the error, and callers that probe for artifacts
//! first (the CLI, tests, benches) skip the PJRT path entirely.
//!
//! To enable the real backend, point the `xla` path dependency in
//! `rust/Cargo.toml` at the published crate.

/// Error raised by every stub entry point.
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what}: built with the offline xla stub (no PJRT backend); \
         point the `xla` dependency at the real crate to enable AOT artifacts"
    ))
}

/// PJRT client handle (stub).
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }
}

/// Parsed HLO module (stub).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(unavailable("HloModuleProto::from_text_file"))
    }
}

/// XLA computation wrapper (stub).
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Host literal (stub).
pub struct Literal;

impl Literal {
    pub fn vec1(_values: &[f32]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(unavailable("Literal::reshape"))
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(unavailable("Literal::to_tuple"))
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(unavailable("Literal::to_vec"))
    }
}

/// Device buffer (stub).
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// Loaded executable (stub).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_fails_loudly_and_actionably() {
        let err = PjRtClient::cpu().err().unwrap();
        let msg = format!("{err:?}");
        assert!(msg.contains("offline xla stub"), "{msg}");
    }
}
