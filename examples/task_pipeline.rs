//! The paper's trace-preprocessing pipeline end to end (Sec. VII-A):
//! synthetic task streams (MapReduce-style anti-affine waves + singleton
//! jobs) → first-fit packing onto fixed-capacity instances → per-slot
//! demand curve → instance-acquisition policies.
//!
//! Exercises the scheduler substrate that turns raw *task* workloads into
//! the demand curves the algorithms consume.
//!
//! Run: `cargo run --release --example task_pipeline`

use cloudreserve::algos::baselines::AllOnDemand;
use cloudreserve::algos::deterministic::Deterministic;
use cloudreserve::algos::randomized::Randomized;
use cloudreserve::pricing::Pricing;
use cloudreserve::sim::run_policy;
use cloudreserve::trace::scheduler::{demand_curve, synth_tasks, Capacity};
use cloudreserve::util::cli::Args;
use cloudreserve::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let slots = args.usize_or("slots", 20_000);
    let tenants = args.usize_or("tenants", 8);
    let mut rng = Rng::new(args.u64_or("seed", 5));
    let pricing = Pricing::normalized(0.08 / 69.0, 0.4875, 8760);

    println!("task → instance pipeline: {tenants} tenants x {slots} slots");
    println!(
        "\n{:<8} {:>7} {:>9} {:>9} {:>12} {:>12} {:>12}",
        "tenant", "#tasks", "peak", "mean", "on-demand", "A_beta", "randomized"
    );

    let mut total_od = 0.0;
    let mut total_det = 0.0;
    let mut total_rand = 0.0;
    for tenant in 0..tenants {
        // each tenant submits at a different intensity
        let intensity = 1.0 / (20.0 + rng.f64() * 200.0);
        let tasks = synth_tasks(slots, intensity, &mut rng);
        let demand = demand_curve(&tasks, Capacity::default(), slots);
        let s = cloudreserve::util::stats::summarize_u32(&demand);

        let mut od = AllOnDemand::new();
        let mut det = Deterministic::online(pricing);
        let mut rnd = Randomized::online(pricing, 1000 + tenant as u64);
        let c_od = run_policy(&mut od, &demand, pricing)?.total;
        let c_det = run_policy(&mut det, &demand, pricing)?.total;
        let c_rnd = run_policy(&mut rnd, &demand, pricing)?.total;
        total_od += c_od;
        total_det += c_det;
        total_rand += c_rnd;
        println!(
            "{:<8} {:>7} {:>9} {:>9.2} {:>12.4} {:>12.4} {:>12.4}",
            tenant,
            tasks.len(),
            s.max,
            s.mean,
            c_od,
            c_det,
            c_rnd
        );
    }
    println!(
        "\nfleet total: on-demand {total_od:.3}, A_beta {total_det:.3} ({:.1}% saved), randomized {total_rand:.3} ({:.1}% saved)",
        100.0 * (1.0 - total_det / total_od),
        100.0 * (1.0 - total_rand / total_od)
    );
    Ok(())
}
