//! Ablation: how does the reservation threshold `z` (the aggressiveness
//! knob of the `A_z` family, Sec. V-A) shape cost across user groups?
//!
//! This is the design-choice study behind the randomized algorithm: the
//! density f(z) of Eq. (24) is a bet on the *shape* of cost(z). The sweep
//! shows that shape per group on the synthetic population:
//! * Group 1 (sporadic): cost rises steeply as z → 0 (fees on bursts) —
//!   conservative wins; `A_β` ≈ All-on-demand.
//! * Group 3 (stable): cost(z) is nearly flat with a mild minimum at
//!   small z — aggressive wins slightly (this is where randomization
//!   pays off).
//! * Group 2: the interesting regime the paper targets.
//!
//! Also prints the mixture expectation under f(z) for comparison with the
//! measured Randomized row of Table II.
//!
//! Run: `cargo run --release --example ablation_threshold_sweep -- --users 150`

use cloudreserve::algos::density;
use cloudreserve::algos::deterministic::Deterministic;
use cloudreserve::analysis::classify::{classify, Group};
use cloudreserve::pricing::catalog::ec2_small_compressed;
use cloudreserve::sim::run_policy;
use cloudreserve::trace::synth::{generate, SynthConfig};
use cloudreserve::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let cfg = SynthConfig {
        users: args.usize_or("users", 150),
        slots: args.usize_or("slots", cloudreserve::trace::TRACE_SLOTS),
        seed: args.u64_or("seed", 2013),
        ..Default::default()
    };
    let pop = generate(&cfg);
    let pricing = ec2_small_compressed();
    let beta = pricing.beta();
    let steps = args.usize_or("steps", 10);

    // normalized cost per (group, z-step), averaged over users
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    println!(
        "threshold sweep: {} users x {} slots, z in {{0, .., beta={beta:.3}}}",
        cfg.users, cfg.slots
    );
    println!(
        "{:>8} {:>9} {:>10} {:>10} {:>10} {:>10}",
        "z", "z/beta", "all", "G1", "G2", "G3"
    );
    let mut curve: Vec<(f64, [f64; 4])> = Vec::new();
    for i in 0..=steps {
        let z = beta * i as f64 / steps as f64;
        let mut sums = [0.0f64; 4];
        let mut counts = [0usize; 4];
        let results: Vec<(Group, f64)> = std::thread::scope(|scope| {
            let handles: Vec<_> = (0..threads)
                .map(|shard| {
                    let pop = &pop;
                    scope.spawn(move || {
                        let mut out = Vec::new();
                        let mut idx = shard;
                        while idx < pop.users.len() {
                            let u = &pop.users[idx];
                            let mut a = Deterministic::with_threshold(pricing, z);
                            let c = run_policy(&mut a, &u.demand, pricing).unwrap().total;
                            let denom = pricing.p * u.total_demand() as f64;
                            if denom > 0.0 {
                                out.push((classify(&u.summary()), c / denom));
                            }
                            idx += threads;
                        }
                        out
                    })
                })
                .collect();
            handles.into_iter().flat_map(|h| h.join().unwrap()).collect()
        });
        for (g, v) in results {
            sums[0] += v;
            counts[0] += 1;
            let gi = match g {
                Group::G1Sporadic => 1,
                Group::G2Medium => 2,
                Group::G3Stable => 3,
            };
            sums[gi] += v;
            counts[gi] += 1;
        }
        let row: [f64; 4] =
            std::array::from_fn(
                |j| if counts[j] > 0 { sums[j] / counts[j] as f64 } else { f64::NAN },
            );
        println!(
            "{z:>8.3} {:>9.2} {:>10.4} {:>10.4} {:>10.4} {:>10.4}",
            z / beta,
            row[0],
            row[1],
            row[2],
            row[3]
        );
        curve.push((z, row));
    }

    // expectation under the Eq. (24) density (trapezoid over the sweep +
    // the atom at beta) — the Randomized row this ablation predicts.
    let alpha = pricing.alpha;
    let mut expect = [0.0f64; 4];
    for w in curve.windows(2) {
        let (z0, r0) = w[0];
        let (z1, r1) = w[1];
        let f0 = density::pdf_continuous(alpha, z0);
        let f1 = density::pdf_continuous(alpha, z1.min(beta * 0.999_999));
        for j in 0..4 {
            expect[j] += 0.5 * (f0 * r0[j] + f1 * r1[j]) * (z1 - z0);
        }
    }
    let atom = density::atom_mass(alpha);
    let last = curve.last().unwrap().1;
    for j in 0..4 {
        expect[j] += atom * last[j];
    }
    println!(
        "\nE_f(z)[cost] (predicted Randomized row): all={:.3} G1={:.3} G2={:.3} G3={:.3}",
        expect[0], expect[1], expect[2], expect[3]
    );
    Ok(())
}
