//! Fig. 5 + Table II reproduction: the full Sec. VII trace-driven
//! evaluation — five policies over the synthetic Google-like population,
//! EC2 compressed pricing, CDFs of per-user cost normalized to
//! All-on-demand, split by demand-fluctuation group.
//!
//! Run (full scale, ~1 min): `cargo run --release --example fig5_cost_cdf`
//! Faster: `cargo run --release --example fig5_cost_cdf -- --users 200 --slots 10000`

use cloudreserve::analysis::classify::Group;
use cloudreserve::analysis::report::{cdf_csv, render_cdf_table, render_table2, CostSeries};
use cloudreserve::pricing::catalog::ec2_small_compressed;
use cloudreserve::pricing::Market;
use cloudreserve::sim::fleet::run_benchmark_suite;
use cloudreserve::trace::synth::{generate, SynthConfig};
use cloudreserve::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let cfg = SynthConfig {
        users: args.usize_or("users", cloudreserve::trace::NUM_USERS),
        slots: args.usize_or("slots", cloudreserve::trace::TRACE_SLOTS),
        seed: args.u64_or("seed", 2013),
        ..Default::default()
    };
    let threads = args.usize_or(
        "threads",
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4),
    );
    eprintln!("population: {} users x {} slots (seed {})", cfg.users, cfg.slots, cfg.seed);
    let pop = generate(&cfg);
    let market = Market::single(ec2_small_compressed());

    let t0 = std::time::Instant::now();
    let results = run_benchmark_suite(&pop, &market, args.u64_or("policy-seed", 1), threads);
    eprintln!("suite finished in {:.1}s", t0.elapsed().as_secs_f64());

    // Table II
    let rows: Vec<(String, [f64; 4])> =
        results.iter().map(|r| (r.policy.clone(), r.table2_row())).collect();
    print!("{}", render_table2(&rows));
    println!("paper reference rows (Google traces):");
    println!("  All-reserved   16.48  48.99  1.25  0.61");
    println!("  Separate        0.88   1.01  1.02  0.71");
    println!("  Deterministic   0.81   1.00  0.89  0.67");
    println!("  Randomized      0.76   1.02  0.79  0.63");

    // Fig. 5 a-d: CDFs for all users + each group
    let panels: [(&str, Option<Group>); 4] = [
        ("Fig. 5a — all users", None),
        ("Fig. 5b — Group 1 (sporadic)", Some(Group::G1Sporadic)),
        ("Fig. 5c — Group 2 (medium)", Some(Group::G2Medium)),
        ("Fig. 5d — Group 3 (stable)", Some(Group::G3Stable)),
    ];
    for (title, group) in panels {
        let series: Vec<CostSeries> = results
            .iter()
            .map(|r| CostSeries { name: r.policy.clone(), values: r.normalized(group) })
            .collect();
        if series[0].values.is_empty() {
            println!("\n{title}: (no users in group)");
            continue;
        }
        println!();
        print!("{}", render_cdf_table(title, &series, 0.0, 2.0, 21));
    }

    if let Some(path) = args.get("csv-out") {
        let series: Vec<CostSeries> = results
            .iter()
            .map(|r| CostSeries { name: r.policy.clone(), values: r.normalized(None) })
            .collect();
        std::fs::write(path, cdf_csv(&series, 0.0, 5.0, 251))?;
        eprintln!("wrote {path}");
    }
    Ok(())
}
