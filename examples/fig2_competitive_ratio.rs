//! Fig. 2 reproduction: competitive ratios of the deterministic and
//! randomized algorithms vs the reservation discount α.
//!
//! Two series per algorithm:
//! * the analytic curves `2−α` and `e/(e−1+α)` the paper plots, and
//! * *measured* worst-case ratios on the break-even adversary family
//!   (demand pulses stopping at / just past β), with exact single-instance
//!   offline OPT as the denominator.
//!
//! The measured deterministic ratio matches `2−α`. The measured randomized
//! ratio matches `e/(e−1+α)` at x = β and exceeds it by
//! `α(1−α)/(e−1+α)` just past β — the documented deviation from Prop. 3
//! (see PERF.md §Known deviations).
//!
//! Run: `cargo run --release --example fig2_competitive_ratio`

use cloudreserve::algos::deterministic::Deterministic;
use cloudreserve::algos::offline;
use cloudreserve::algos::randomized::Randomized;
use cloudreserve::pricing::Pricing;
use cloudreserve::sim::run_policy;

fn measured_det_ratio(alpha: f64, p: f64) -> f64 {
    let pricing = Pricing::normalized(p, alpha, 10_000_000);
    let pulses = (pricing.beta() / p).ceil() as usize + 1;
    let mut demands = vec![1u32; pulses];
    demands.extend(vec![0u32; 5]);
    let mut a = Deterministic::online(pricing);
    let cost = run_policy(&mut a, &demands, pricing).unwrap().total;
    cost / offline::optimal_single(&demands, &pricing).cost
}

fn measured_rand_ratio(alpha: f64, p: f64, at_beta: bool, samples: u64) -> f64 {
    let pricing = Pricing::normalized(p, alpha, 10_000_000);
    let pulses = if at_beta {
        (pricing.beta() / p).floor() as usize
    } else {
        (pricing.beta() / p).ceil() as usize + 1
    };
    let demands = vec![1u32; pulses];
    let opt = offline::optimal_single(&demands, &pricing).cost;
    let mean: f64 = (0..samples)
        .map(|s| {
            let mut a = Randomized::online(pricing, s * 31 + 7);
            run_policy(&mut a, &demands, pricing).unwrap().total
        })
        .sum::<f64>()
        / samples as f64;
    mean / opt
}

fn main() {
    let p = 0.004;
    let samples = 1500;
    println!("Fig. 2 — competitive ratio vs reservation discount alpha (p={p}, {samples} draws)");
    println!(
        "{:>6} {:>10} {:>12} {:>12} {:>14} {:>16}",
        "alpha", "2-a", "det(meas)", "e/(e-1+a)", "rand@beta", "rand@beta+eps"
    );
    for i in 0..=10 {
        let alpha = i as f64 / 10.0;
        let pricing = Pricing::normalized(p, alpha.min(0.999), 1000);
        let det_analytic = pricing.deterministic_ratio();
        let rand_analytic = pricing.randomized_ratio();
        if alpha >= 0.999 {
            // alpha = 1: reserving never helps; every algorithm is optimal.
            println!(
                "{alpha:>6.2} {det_analytic:>10.4} {:>12.4} {rand_analytic:>12.4} {:>14.4} {:>16.4}",
                1.0, 1.0, 1.0
            );
            continue;
        }
        let det_meas = measured_det_ratio(alpha, p);
        let rand_at_beta = measured_rand_ratio(alpha, p, true, samples);
        let rand_past_beta = measured_rand_ratio(alpha, p, false, samples);
        println!(
            "{alpha:>6.2} {det_analytic:>10.4} {det_meas:>12.4} {rand_analytic:>12.4} {rand_at_beta:>14.4} {rand_past_beta:>16.4}"
        );
    }
    println!(
        "\nEC2 light-utilization alpha=0.4875: deterministic {:.2}x, randomized {:.2}x (paper: 1.51 / 1.23)",
        Pricing::normalized(p, 0.4875, 1000).deterministic_ratio(),
        Pricing::normalized(p, 0.4875, 1000).randomized_ratio()
    );
}
