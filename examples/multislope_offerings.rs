//! Extension experiment (paper Sec. IX): combining multiple reserved
//! offerings through the first-class Market API. Runs the generalized
//! deterministic menu policy over the two-term EC2 catalog market
//! (1-year + 3-year Standard Small, compressed) across the synthetic
//! population, against the best *single*-contract alternatives — the
//! question the paper leaves open.
//!
//! Run: `cargo run --release --example multislope_offerings -- --users 150`
//!
//! Ad-hoc menus are a config file away: see the `scenario` subcommand and
//! `examples/scenarios/table1_two_term.json`.

use cloudreserve::algos::market::MarketDeterministic;
use cloudreserve::analysis::classify::{classify, Group};
use cloudreserve::pricing::catalog::ec2_two_term_compressed;
use cloudreserve::pricing::Market;
use cloudreserve::sim::run_policy_market;
use cloudreserve::trace::synth::{generate, SynthConfig};
use cloudreserve::util::cli::Args;

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let cfg = SynthConfig {
        users: args.usize_or("users", 150),
        slots: args.usize_or("slots", cloudreserve::trace::TRACE_SLOTS),
        seed: args.u64_or("seed", 2013),
        ..Default::default()
    };
    let pop = generate(&cfg);
    let market = ec2_two_term_compressed();
    let shallow_only = Market::new(market.p(), vec![market.contract(0)]);
    let deep_only = Market::new(market.p(), vec![market.contract(1)]);

    println!(
        "two-term menu: {} (fee {:.2}, a={:.3}, term={}) + {} (fee {:.2}, a={:.3}, term={})",
        market.label(0),
        market.contract(0).upfront,
        market.alpha(0),
        market.contract(0).term,
        market.label(1),
        market.contract(1).upfront,
        market.alpha(1),
        market.contract(1).term,
    );
    println!(
        "\n{:<10} {:>12} {:>12} {:>12} {:>14} {:>10}",
        "group", "menu", "1y-only", "3y-only", "menu vs best", "users"
    );

    let run = |m: &Market, demand: &[u32]| -> f64 {
        let mut policy = MarketDeterministic::new(m.clone());
        run_policy_market(&mut policy, demand, m).expect("feasible billing").total
    };

    let mut acc: Vec<(Group, f64, f64, f64)> = Vec::new();
    for u in &pop.users {
        let denom = market.p() * u.total_demand() as f64;
        if denom <= 0.0 {
            continue;
        }
        let m = run(&market, &u.demand) / denom;
        let s = run(&shallow_only, &u.demand) / denom;
        let d = run(&deep_only, &u.demand) / denom;
        acc.push((classify(&u.summary()), m, s, d));
    }

    for (label, group) in [
        ("All", None),
        ("G1", Some(Group::G1Sporadic)),
        ("G2", Some(Group::G2Medium)),
        ("G3", Some(Group::G3Stable)),
    ] {
        let rows: Vec<&(Group, f64, f64, f64)> = acc
            .iter()
            .filter(|(g, ..)| group.map(|gg| *g == gg).unwrap_or(true))
            .collect();
        if rows.is_empty() {
            continue;
        }
        let n = rows.len() as f64;
        let menu_m = rows.iter().map(|r| r.1).sum::<f64>() / n;
        let sh_m = rows.iter().map(|r| r.2).sum::<f64>() / n;
        let dp_m = rows.iter().map(|r| r.3).sum::<f64>() / n;
        println!(
            "{:<10} {:>12.4} {:>12.4} {:>12.4} {:>13.1}% {:>10}",
            label,
            menu_m,
            sh_m,
            dp_m,
            100.0 * (menu_m / sh_m.min(dp_m) - 1.0),
            rows.len()
        );
    }
    println!("\n(menu vs best = mean menu cost relative to the ex-post better single contract)");
}
