//! Extension experiment (paper Sec. IX): combining multiple reserved
//! offerings. Runs the generalized deterministic policy over a two-tier
//! EC2-style menu (1-year light + 3-year heavy utilization, compressed)
//! across the synthetic population, against the best *single*-offering
//! alternatives — the question the paper leaves open.
//!
//! Run: `cargo run --release --example multislope_offerings -- --users 150`

use cloudreserve::algos::multislope::{Menu, MultiDeterministic};
use cloudreserve::analysis::classify::{classify, Group};
use cloudreserve::trace::synth::{generate, SynthConfig};
use cloudreserve::util::cli::Args;

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let cfg = SynthConfig {
        users: args.usize_or("users", 150),
        slots: args.usize_or("slots", cloudreserve::trace::TRACE_SLOTS),
        seed: args.u64_or("seed", 2013),
        ..Default::default()
    };
    let pop = generate(&cfg);
    let menu = Menu::ec2_two_tier_compressed();
    let shallow_only = Menu::new(menu.p, vec![menu.offerings[0]]);
    let deep_only = Menu::new(menu.p, vec![menu.offerings[1]]);

    println!(
        "two-tier menu: 1y-light (fee 1.00, a={:.3}, tau={}) + 3y-heavy (fee {:.2}, a={:.3}, tau={})",
        menu.offerings[0].alpha,
        menu.offerings[0].tau,
        menu.offerings[1].fee,
        menu.offerings[1].alpha,
        menu.offerings[1].tau
    );
    println!(
        "\n{:<10} {:>12} {:>12} {:>12} {:>14} {:>10}",
        "group", "menu", "1y-only", "3y-only", "menu vs best", "users"
    );

    let mut acc: Vec<(Group, f64, f64, f64)> = Vec::new();
    for u in &pop.users {
        let denom = menu.p * u.total_demand() as f64;
        if denom <= 0.0 {
            continue;
        }
        let m = MultiDeterministic::run(menu.clone(), &u.demand).total / denom;
        let s = MultiDeterministic::run(shallow_only.clone(), &u.demand).total / denom;
        let d = MultiDeterministic::run(deep_only.clone(), &u.demand).total / denom;
        acc.push((classify(&u.summary()), m, s, d));
    }

    for (label, group) in [
        ("All", None),
        ("G1", Some(Group::G1Sporadic)),
        ("G2", Some(Group::G2Medium)),
        ("G3", Some(Group::G3Stable)),
    ] {
        let rows: Vec<&(Group, f64, f64, f64)> = acc
            .iter()
            .filter(|(g, ..)| group.map(|gg| *g == gg).unwrap_or(true))
            .collect();
        if rows.is_empty() {
            continue;
        }
        let n = rows.len() as f64;
        let menu_m = rows.iter().map(|r| r.1).sum::<f64>() / n;
        let sh_m = rows.iter().map(|r| r.2).sum::<f64>() / n;
        let dp_m = rows.iter().map(|r| r.3).sum::<f64>() / n;
        println!(
            "{:<10} {:>12.4} {:>12.4} {:>12.4} {:>13.1}% {:>10}",
            label,
            menu_m,
            sh_m,
            dp_m,
            100.0 * (menu_m / sh_m.min(dp_m) - 1.0),
            rows.len()
        );
    }
    println!("\n(menu vs best = mean menu cost relative to the ex-post better single offering)");
}
