//! Fig. 3 + Fig. 4 reproduction: one user's month-long demand curve and
//! the population's (mean, σ/μ) scatter with the three-group division.
//!
//! Run: `cargo run --release --example fig3_fig4_population`

use cloudreserve::analysis::classify::{classify_population, group_counts, Group};
use cloudreserve::analysis::report::render_fig4_scatter;
use cloudreserve::trace::synth::{generate, SynthConfig};
use cloudreserve::trace::SLOTS_PER_DAY;
use cloudreserve::util::cli::Args;

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let cfg = SynthConfig {
        users: args.usize_or("users", cloudreserve::trace::NUM_USERS),
        slots: args.usize_or("slots", cloudreserve::trace::TRACE_SLOTS),
        seed: args.u64_or("seed", 2013),
        ..Default::default()
    };
    let pop = generate(&cfg);

    // ---- Fig. 3: pick the group-2 user whose demand is "bursty with
    // structure", like Google user 552 in the paper.
    let rows = classify_population(&pop);
    let fig3_user = args
        .get("user")
        .and_then(|s| s.parse::<u32>().ok())
        .or_else(|| {
            rows.iter()
                .filter(|(_, g, mean, _)| *g == Group::G2Medium && *mean > 5.0)
                .map(|(uid, _, _, _)| *uid)
                .next()
        })
        .unwrap_or(0);
    let user = pop.users.iter().find(|u| u.user_id == fig3_user).expect("user exists");
    println!("Fig. 3 — demand curve of user {fig3_user} over the month (hourly means, '#' = 1/8 of peak):");
    let hourly: Vec<f64> = user
        .demand
        .chunks(60)
        .map(|c| cloudreserve::util::stats::summarize_u32(c).mean)
        .collect();
    let peak = hourly.iter().cloned().fold(1e-9, f64::max);
    // one line per day, 24 buckets
    for (day, day_hours) in hourly.chunks(24).enumerate().take(cfg.slots / SLOTS_PER_DAY) {
        let line: String = day_hours
            .iter()
            .map(|&h| {
                let level = (8.0 * h / peak).round() as usize;
                [' ', '.', ':', '-', '=', '+', '*', '#', '#'][level.min(8)]
            })
            .collect();
        println!("  day {day:>2} |{line}|");
    }
    println!("  (peak hourly mean = {peak:.1} instances)");

    // ---- Fig. 4: the scatter + group shares
    let (g1, g2, g3) = group_counts(&pop);
    println!(
        "\nFig. 4 — {} users: Group1={g1} ({:.0}%)  Group2={g2} ({:.0}%)  Group3={g3} ({:.0}%)",
        pop.len(),
        100.0 * g1 as f64 / pop.len() as f64,
        100.0 * g2 as f64 / pop.len() as f64,
        100.0 * g3 as f64 / pop.len() as f64
    );
    let pts: Vec<(f64, f64)> = rows.iter().map(|(_, _, mean, cov)| (*mean, *cov)).collect();
    print!("{}", render_fig4_scatter(&pts, 72, 22));
}
