//! Fig. 6 + Fig. 7 reproduction: the value of short-term predictions.
//!
//! The paper evaluates `A^w_β` (Fig. 6) and the randomized `A^w_z`
//! (Fig. 7) with prediction windows of 1, 2 and 3 months *of original
//! time*. Under the Sec. VII compression (1 year → 8760 minutes), one
//! month is 8760/12 = 730 slots, so w ∈ {730, 1460, 2190} < τ = 8760.
//! Costs are normalized to the corresponding *online* algorithm (w = 0),
//! and reported as CDFs over users plus per-group means.
//!
//! Predictions use the paper's reliability assumption: the future window
//! is read from the actual trace (an oracle). `--forecast` switches to the
//! streaming AR(8) forecaster to measure how much of the gain survives
//! real predictions (an extension beyond the paper).
//!
//! Run: `cargo run --release --example fig6_fig7_prediction -- --users 300 --slots 20000`

use cloudreserve::analysis::classify::{classify, Group};
use cloudreserve::analysis::report::{render_cdf_table, CostSeries};
use cloudreserve::forecast::{ArForecaster, Forecaster};
use cloudreserve::pricing::catalog::ec2_small_compressed;
use cloudreserve::sim::{run_policy, run_policy_with};
use cloudreserve::trace::synth::{generate, SynthConfig};
use cloudreserve::util::cli::Args;
use cloudreserve::Policy;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let cfg = SynthConfig {
        users: args.usize_or("users", cloudreserve::trace::NUM_USERS),
        slots: args.usize_or("slots", cloudreserve::trace::TRACE_SLOTS),
        seed: args.u64_or("seed", 2013),
        ..Default::default()
    };
    let use_forecaster = args.has("forecast");
    let pop = generate(&cfg);
    let pricing = ec2_small_compressed();
    // windows: 1, 2, 3 months of original time, compressed; clamp for
    // short --slots runs so w < tau and w << T stay meaningful.
    let month = 8760 / 12;
    let windows: Vec<usize> = [month, 2 * month, 3 * month]
        .iter()
        .map(|&w| w.min(pricing.tau - 1).min(cfg.slots / 4))
        .collect();

    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    let figs = [("Fig. 6 (deterministic A^w_beta)", false), ("Fig. 7 (randomized A^w_z)", true)];
    for (fig, randomized) in figs {
        eprintln!("computing {fig}...");
        // per window: per-user cost normalized to the online counterpart
        let mut series: Vec<CostSeries> = Vec::new();
        let mut group_means: Vec<(String, [f64; 4])> = Vec::new();
        for &w in &windows {
            let t0 = std::time::Instant::now();
            let normalized = run_window(&pop, pricing, w, randomized, use_forecaster, threads);
            eprintln!("  w={w} done in {:.1}s", t0.elapsed().as_secs_f64());
            // group means
            let mut sums = [0.0f64; 4];
            let mut counts = [0usize; 4];
            for (g, v) in &normalized {
                sums[0] += v;
                counts[0] += 1;
                let gi = match g {
                    Group::G1Sporadic => 1,
                    Group::G2Medium => 2,
                    Group::G3Stable => 3,
                };
                sums[gi] += v;
                counts[gi] += 1;
            }
            let means = std::array::from_fn(
                |i| if counts[i] > 0 { sums[i] / counts[i] as f64 } else { f64::NAN },
            );
            group_means.push((format!("w={w} slots (~{} months)", w / month.max(1)), means));
            series.push(CostSeries {
                name: format!("w={w}"),
                values: normalized.iter().map(|(_, v)| *v).collect(),
            });
        }
        println!("\n{fig} — cost normalized to the online algorithm (w=0)");
        println!(
            "{:<28} {:>10} {:>10} {:>10} {:>10}",
            "window", "All users", "Group 1", "Group 2", "Group 3"
        );
        for (name, m) in &group_means {
            println!("{:<28} {:>10.3} {:>10.3} {:>10.3} {:>10.3}", name, m[0], m[1], m[2], m[3]);
        }
        println!();
        print!("{}", render_cdf_table(&format!("{fig} — CDF"), &series, 0.5, 1.1, 13));
    }
    if use_forecaster {
        println!("\n(predictions from streaming AR(8) forecaster, not the oracle)");
    }
    Ok(())
}

/// Returns per-user (group, cost_w / cost_online).
fn run_window(
    pop: &cloudreserve::trace::Population,
    pricing: cloudreserve::Pricing,
    w: usize,
    randomized: bool,
    use_forecaster: bool,
    threads: usize,
) -> Vec<(Group, f64)> {
    use std::sync::mpsc;
    let (tx, rx) = mpsc::channel();
    std::thread::scope(|scope| {
        for shard in 0..threads {
            let tx = tx.clone();
            scope.spawn(move || {
                let mut out = Vec::new();
                let mut idx = shard;
                while idx < pop.users.len() {
                    let u = &pop.users[idx];
                    let group = classify(&u.summary());
                    let mk = |win: usize| -> Box<dyn Policy> {
                        if randomized {
                            Box::new(cloudreserve::algos::randomized::Randomized::with_window(
                                pricing,
                                win,
                                0xF1675 ^ ((u.user_id as u64) << 13),
                            ))
                        } else {
                            Box::new(cloudreserve::algos::deterministic::Deterministic::with_window(
                                pricing, win,
                            ))
                        }
                    };
                    let mut online = mk(0);
                    let base = run_policy(online.as_mut(), &u.demand, pricing).unwrap().total;
                    let mut pred = mk(w);
                    let cost = if use_forecaster {
                        let mut f = ArForecaster::new(8, 128, 1024);
                        // reusable forecast buffers: the slot loop performs
                        // no allocation once these reach the window size
                        let mut f64_buf: Vec<f64> = Vec::new();
                        let mut scratch: Vec<f64> = Vec::new();
                        run_policy_with(pred.as_mut(), &u.demand, pricing, |t, buf| {
                            // observe up to t, fill the reusable buffer
                            // with the next-w prediction
                            f.observe(u.demand[t]);
                            f.predict_f64_into(w, &mut f64_buf, &mut scratch);
                            buf.extend(f64_buf.iter().map(|y| y.round().max(0.0) as u32));
                        })
                        .unwrap()
                        .total
                    } else {
                        run_policy(pred.as_mut(), &u.demand, pricing).unwrap().total
                    };
                    out.push((group, if base > 0.0 { cost / base } else { 1.0 }));
                    idx += threads;
                }
                tx.send(out).unwrap();
            });
        }
        drop(tx);
        rx.iter().flatten().collect()
    })
}
