//! The L3 brokerage as a long-running service: stream a multi-tenant
//! demand feed through the sharded broker, run the AR-forecast-driven
//! prediction-window policy per user, and tick the PJRT analytics engine
//! (L1 Pallas window scan through the AOT artifact) every N slots.
//!
//! This is the paper's system as a downstream user would deploy it:
//! no oracle, no offline pass — pure online operation. Every user is
//! billed in isolation, which makes this the "isolated users" baseline
//! for the shared-portfolio broker (`cloudreserve::broker`, CLI
//! subcommand `broker`): the same fleet run through the aggregate
//! portfolio realizes a multiplexing gain over the per-user total
//! reported here.
//!
//! Run: `cargo run --release --example broker_service -- --users 96 --slots 4000`

use cloudreserve::coordinator::{AnalyticsEngine, Broker, BrokerConfig, DemandEvent, PolicyKind};
use cloudreserve::pricing::catalog::ec2_small_compressed;
use cloudreserve::trace::synth::{generate, SynthConfig};
use cloudreserve::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let users = args.usize_or("users", 96);
    let slots = args.usize_or("slots", 4000);
    let tick_every = args.usize_or("tick", 1000);
    let pricing = ec2_small_compressed();

    let cfg = BrokerConfig {
        pricing,
        shards: args.usize_or("shards", 4),
        queue_capacity: 8192,
        window: 64,
    };
    // Real online operation: deterministic policy with a 2-hour prediction
    // window fed by the per-user streaming AR(8) forecaster.
    let broker = Broker::start(cfg, PolicyKind::DeterministicForecast { window: 120, ar_order: 8 });

    let engine = {
        let dir = args.str_or("artifacts", "artifacts");
        if std::path::Path::new(&dir).join("manifest.json").exists() {
            let rt = cloudreserve::runtime::Runtime::load_filtered(&dir, |n| {
                n.starts_with("fleet_step")
            })?;
            eprintln!("analytics on PJRT {} ({:?})", rt.platform(), rt.names());
            Some(AnalyticsEngine::new(rt, pricing, 16, 128))
        } else {
            eprintln!("no artifacts: analytics disabled (run `make artifacts`)");
            None
        }
    };

    let seed = args.u64_or("seed", 77);
    let pop = generate(&SynthConfig { users, slots, seed, ..Default::default() });
    let t0 = std::time::Instant::now();
    for t in 0..slots {
        for u in &pop.users {
            broker.submit(DemandEvent { user_id: u.user_id, slot: t as u32, demand: u.demand[t] })?;
        }
        if let Some(engine) = &engine {
            if t % tick_every == tick_every - 1 {
                let posture = engine.tick(&broker)?;
                println!(
                    "[t={t:>6}] fleet posture: mean reserve-pressure {:.3}; over break-even: {:?}",
                    posture.mean_pressure(),
                    posture.over_breakeven()
                );
            }
        }
    }
    let report = broker.finish()?;
    let dt = t0.elapsed().as_secs_f64();

    let all_od: f64 = pop
        .users
        .iter()
        .map(|u| pricing.p * u.total_demand() as f64)
        .sum();
    println!(
        "\nstreamed {} events in {dt:.2}s ({:.0}/s)",
        users * slots,
        (users * slots) as f64 / dt
    );
    println!(
        "fleet bill: {:.2} vs all-on-demand {:.2} ({:.1}% saved), {} reservations",
        report.total_cost(),
        all_od,
        100.0 * (1.0 - report.total_cost() / all_od),
        report.total_reservations()
    );
    Ok(())
}
