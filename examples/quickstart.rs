//! Quickstart: the paper's problem in 80 lines.
//!
//! A user faces time-varying instance demand and must decide online when
//! to reserve. We price with EC2 Standard Small (Table I), run the two
//! online algorithms against the baselines, compare with the exact offline
//! optimum, and (if `make artifacts` has run) push one analytics batch
//! through the AOT-compiled Pallas window-scan on the PJRT runtime.
//!
//! Run: `cargo run --release --example quickstart`

use cloudreserve::algos::baselines::{AllOnDemand, AllReserved, Separate};
use cloudreserve::algos::deterministic::Deterministic;
use cloudreserve::algos::offline;
use cloudreserve::algos::randomized::Randomized;
use cloudreserve::pricing::Pricing;
use cloudreserve::sim::run_policy;
use cloudreserve::Policy;

fn main() -> anyhow::Result<()> {
    // Toy pricing with the EC2 normalized shape but a short reservation
    // period so the whole story fits a few hundred slots:
    // p = on-demand rate (fee-normalized), alpha = reserved discount,
    // tau = reservation period. Break-even beta = 1/(1-alpha) ~ 1.95.
    let pricing = Pricing::normalized(0.02, 0.4875, 200);
    println!(
        "pricing: p={} alpha={} tau={} -> beta={:.3} ({:.0} busy slots per period to justify reserving)",
        pricing.p,
        pricing.alpha,
        pricing.tau,
        pricing.beta(),
        pricing.break_even_hours()
    );

    // A workload with a stable phase (reserving pays off) and a sporadic
    // tail (reserving would be wasted).
    let mut demand: Vec<u32> = Vec::new();
    demand.extend(vec![2u32; 250]); // stable: 2 instances for 250 slots
    demand.extend(vec![0u32; 80]);
    demand.extend([1, 0, 0, 3, 0, 0, 0, 1, 0, 2]); // sporadic pulses
    demand.extend(vec![0u32; 60]);

    let mut policies: Vec<Box<dyn Policy>> = vec![
        Box::new(AllOnDemand::new()),
        Box::new(AllReserved::new(pricing)),
        Box::new(Separate::new(pricing)),
        Box::new(Deterministic::online(pricing)), // Algorithm 1
        Box::new(Randomized::online(pricing, 42)), // Algorithm 2
    ];

    println!("\n{:<28} {:>10} {:>8} {:>10}", "policy", "cost", "#res", "vs on-dem");
    let all_od = cloudreserve::sim::all_on_demand_cost(&demand, pricing.p);
    for policy in policies.iter_mut() {
        let rep = run_policy(policy.as_mut(), &demand, pricing)?;
        println!(
            "{:<28} {:>10.3} {:>8} {:>9.0}%",
            policy.name(),
            rep.total,
            rep.reservations,
            100.0 * rep.total / all_od
        );
    }

    // Exact offline optimum. The DP is exponential in tau (the paper's
    // Sec. III intractability), so demonstrate Prop. 1 on a small instance.
    let small = Pricing::normalized(0.3, 0.4875, 6);
    let toy: Vec<u32> = (0..40).map(|t| [2, 2, 2, 1, 0, 0, 3, 2][(t / 5) % 8]).collect();
    let opt = offline::optimal(&toy, &small);
    let mut det = Deterministic::online(small);
    let det_cost = run_policy(&mut det, &toy, small)?.total;
    println!(
        "\nsmall instance (tau=6): offline OPT = {:.3} ({} reservations); \
         A_beta/OPT = {:.3} <= {:.3} = 2-alpha  (Prop. 1)",
        opt.cost,
        opt.reservations,
        det_cost / opt.cost,
        small.deterministic_ratio()
    );

    // The L1/L2 layers: one fleet-analytics batch through the AOT artifact.
    let dir = std::path::Path::new("artifacts");
    if dir.join("manifest.json").exists() {
        let rt = cloudreserve::runtime::Runtime::load_filtered(dir, |n| {
            n.starts_with("fleet_step_b8")
        })?;
        // 1 user, last-64-slot window, never-covered demand
        let window = 64;
        let tail: Vec<f32> = demand[..window].iter().map(|&d| d as f32).collect();
        let coverage = vec![0.0f32; window];
        let z_probe = [0.0, pricing.beta() as f32];
        let out = rt.fleet_step(pricing.p, &tail, &coverage, 1, window, &z_probe)?;
        println!(
            "\nPJRT analytics (platform {}): window violations = {}, A_0 would reserve: {}, A_beta would reserve: {}",
            rt.platform(),
            out.counts[0],
            out.decided(0, 0),
            out.decided(0, 1),
        );
    } else {
        println!("\n(skip PJRT demo: run `make artifacts` first)");
    }
    Ok(())
}
