"""AOT artifact checks: the emitted HLO text must exist, parse, and match
the manifest's shape catalog. Guards the Python->Rust interchange contract.
"""

import json
import os

import pytest

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def _manifest():
    path = os.path.join(ART_DIR, "manifest.json")
    if not os.path.exists(path):
        pytest.skip("artifacts not built (run `make artifacts`)")
    with open(path) as f:
        return json.load(f)


def test_manifest_lists_expected_kinds():
    manifest = _manifest()
    kinds = {e["kind"] for e in manifest}
    assert {"fleet_step", "ar_forecast", "cost_summary"} <= kinds


def test_all_artifacts_exist_and_are_hlo_text():
    manifest = _manifest()
    assert len(manifest) >= 5
    for e in manifest:
        path = os.path.join(ART_DIR, e["file"])
        assert os.path.exists(path), f"missing {path}"
        with open(path) as f:
            text = f.read()
        assert "ENTRY" in text, f"{e['file']} does not look like HLO text"
        assert "main" in text
        # 64-bit-id proto issue does not apply to text, but sanity check the
        # parameter count matches the manifest
        n_params = text.count("parameter(")
        assert n_params >= len(e["inputs"]), (
            f"{e['file']}: {n_params} parameters < {len(e['inputs'])} manifest inputs"
        )


def test_manifest_shapes_in_hlo():
    # every input shape in the manifest should appear in the HLO text as
    # f32[dims] for some parameter
    manifest = _manifest()
    for e in manifest:
        path = os.path.join(ART_DIR, e["file"])
        with open(path) as f:
            text = f.read()
        for pname, shape in e["inputs"].items():
            dims = ",".join(str(s) for s in shape)
            assert f"f32[{dims}]" in text, (
                f"{e['file']}: input {pname} f32[{dims}] not found in HLO"
            )


def test_production_fleet_step_variant_present():
    manifest = _manifest()
    names = {e["name"] for e in manifest}
    assert "fleet_step_b128_w8760_k64" in names, (
        "production variant (128 users x compressed reservation period) missing"
    )


def test_artifacts_regenerate_deterministically(tmp_path):
    # re-lower one small artifact and compare against the shipped file
    from compile import aot

    entry = next(e for e in aot.catalog() if e["name"] == "fleet_step_b8_w64_k8")
    text = aot.to_hlo_text(entry["lower"]())
    shipped = os.path.join(ART_DIR, "fleet_step_b8_w64_k8.hlo.txt")
    if not os.path.exists(shipped):
        pytest.skip("artifacts not built")
    with open(shipped) as f:
        assert f.read() == text, "AOT lowering is not reproducible"
