"""L2 correctness: the AOT-compiled graphs against independent references."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref


# ------------------------------------------------------------ fleet_step

def test_fleet_step_equals_ref():
    rng = np.random.default_rng(0)
    b, w, k = 16, 48, 12
    d = rng.integers(0, 6, (b, w)).astype(np.float32)
    x = rng.integers(0, 6, (b, w)).astype(np.float32)
    m = np.ones((b, w), np.float32)
    z = np.linspace(0, 2, k).astype(np.float32)
    p = 0.08 / 69.0
    counts, dec = model.fleet_step(
        jnp.array([p], jnp.float32), jnp.array(d), jnp.array(x), jnp.array(m), jnp.array(z)
    )
    counts_ref, dec_ref = ref.threshold_decisions(
        jnp.array(d), jnp.array(x), jnp.array(m), jnp.array(z), p
    )
    np.testing.assert_allclose(np.asarray(counts), np.asarray(counts_ref))
    np.testing.assert_array_equal(np.asarray(dec), np.asarray(dec_ref))


# ------------------------------------------------------------ ar_forecast

def _numpy_ar(history, coef, horizon):
    b, _ = history.shape
    k = coef.shape[1] - 1
    out = np.zeros((b, horizon), np.float32)
    ext = [history[:, i].astype(np.float64) for i in range(history.shape[1])]
    for h in range(horizon):
        y = coef[:, 0].astype(np.float64).copy()
        for j in range(1, k + 1):
            y += coef[:, j].astype(np.float64) * ext[len(ext) - j]
        y = np.maximum(y, 0.0)
        out[:, h] = y.astype(np.float32)
        ext.append(y)
    return out


@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 12),
    l=st.integers(2, 24),
    k=st.integers(1, 4),
    h=st.integers(1, 12),
    seed=st.integers(0, 2**31 - 1),
)
def test_ar_forecast_matches_numpy(b, l, k, h, seed):
    if l < k:
        l = k
    rng = np.random.default_rng(seed)
    history = rng.integers(0, 20, (b, l)).astype(np.float32)
    # stable-ish coefficients so iteration doesn't blow up numerically
    coef = np.concatenate(
        [rng.random((b, 1)).astype(np.float32) * 5,
         (rng.random((b, k)).astype(np.float32) - 0.2) * 0.5],
        axis=1,
    )
    got = model.ar_forecast(jnp.array(history), jnp.array(coef), horizon=h)
    want = _numpy_ar(history, coef, h)
    np.testing.assert_allclose(np.asarray(got), want, rtol=2e-4, atol=2e-3)


def test_ar_forecast_constant_series():
    # AR fixed point: c + a*v = v with c = v(1-a)
    b, l, k, h = 4, 10, 2, 6
    v = 7.0
    history = np.full((b, l), v, np.float32)
    coef = np.zeros((b, k + 1), np.float32)
    coef[:, 0] = v * 0.5
    coef[:, 1] = 0.5
    got = np.asarray(model.ar_forecast(jnp.array(history), jnp.array(coef), horizon=h))
    np.testing.assert_allclose(got, np.full((b, h), v), rtol=1e-5)


def test_ar_forecast_nonnegative():
    history = np.zeros((3, 8), np.float32)
    coef = np.full((3, 3), -5.0, np.float32)  # wants to go negative
    got = np.asarray(model.ar_forecast(jnp.array(history), jnp.array(coef), horizon=5))
    assert (got >= 0).all()


# --------------------------------------------------------- cost summary

def test_cost_summary_identity():
    # total = fees + od + alpha*p*reserved_use, matching the Rust ledger
    rng = np.random.default_rng(5)
    b, w = 6, 32
    p, alpha = 0.08 / 69.0, 0.4875
    d = rng.integers(0, 5, (b, w)).astype(np.float32)
    o = np.minimum(d, rng.integers(0, 5, (b, w)).astype(np.float32))
    r = rng.integers(0, 2, (b, w)).astype(np.float32)
    m = np.ones((b, w), np.float32)
    out = np.asarray(
        model.fleet_cost_summary(
            jnp.array([p], jnp.float32), jnp.array([alpha], jnp.float32),
            jnp.array(d), jnp.array(o), jnp.array(r), jnp.array(m)
        )
    )
    total, od_cost, fees = out[:, 0], out[:, 1], out[:, 2]
    want_od = (p * o).sum(axis=1)
    want_fees = r.sum(axis=1)
    want_total = want_fees + want_od + alpha * p * (d - o).sum(axis=1)
    np.testing.assert_allclose(od_cost, want_od, rtol=1e-5)
    np.testing.assert_allclose(fees, want_fees, rtol=1e-5)
    np.testing.assert_allclose(total, want_total, rtol=1e-5)


def test_cost_summary_mask_excludes_slots():
    b, w = 2, 4
    d = np.ones((b, w), np.float32)
    o = np.ones((b, w), np.float32)
    r = np.ones((b, w), np.float32)
    m = np.zeros((b, w), np.float32)
    m[:, 0] = 1.0  # only first slot counts
    out = np.asarray(
        model.fleet_cost_summary(
            jnp.array([0.5], jnp.float32), jnp.array([0.0], jnp.float32),
            jnp.array(d), jnp.array(o), jnp.array(r), jnp.array(m)
        )
    )
    np.testing.assert_allclose(out[:, 2], np.ones(b))  # one fee
    np.testing.assert_allclose(out[:, 1], np.full(b, 0.5))  # one od slot


# ------------------------------------------------------------- lowering

def test_fleet_step_lowers_without_python_callbacks():
    # The lowered module must be pure HLO (no host callbacks): the Rust
    # runtime cannot service them.
    spec = jax.ShapeDtypeStruct
    lowered = jax.jit(model.fleet_step).lower(
        spec((1,), jnp.float32),
        spec((8, 16), jnp.float32),
        spec((8, 16), jnp.float32),
        spec((8, 16), jnp.float32),
        spec((4,), jnp.float32),
    )
    text = str(lowered.compiler_ir("stablehlo"))
    assert "stablehlo.custom_call" not in text, "custom call would break PJRT CPU execution"
    assert "callback" not in text
