"""L1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

Hypothesis sweeps shapes/values; explicit cases pin the edge semantics
(strict inequality, mask handling, padding rows).
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref, window_scan


def _case(rng, b, w, demand_hi=6, float_vals=False):
    if float_vals:
        d = (rng.random((b, w)) * demand_hi).astype(np.float32)
        x = (rng.random((b, w)) * demand_hi).astype(np.float32)
    else:
        d = rng.integers(0, demand_hi, (b, w)).astype(np.float32)
        x = rng.integers(0, demand_hi, (b, w)).astype(np.float32)
    m = (rng.random((b, w)) < 0.85).astype(np.float32)
    return d, x, m


# ---------------------------------------------------------------- counts

@settings(max_examples=40, deadline=None)
@given(
    b_blocks=st.integers(1, 4),
    w=st.integers(1, 96),
    seed=st.integers(0, 2**31 - 1),
    demand_hi=st.integers(1, 50),
    float_vals=st.booleans(),
)
def test_counts_match_ref(b_blocks, w, seed, demand_hi, float_vals):
    b = b_blocks * window_scan.DEFAULT_BLOCK_USERS
    rng = np.random.default_rng(seed)
    d, x, m = _case(rng, b, w, demand_hi, float_vals)
    got = window_scan.window_violation_counts(jnp.array(d), jnp.array(x), jnp.array(m))
    want = ref.window_violation_counts(jnp.array(d), jnp.array(x), jnp.array(m))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=0, atol=0)


def test_counts_strict_inequality():
    # d == x is NOT a violation (Algorithm 1 uses d_i > x_i)
    d = jnp.full((8, 4), 3.0)
    x = jnp.full((8, 4), 3.0)
    m = jnp.ones((8, 4))
    got = window_scan.window_violation_counts(d, x, m)
    np.testing.assert_array_equal(np.asarray(got), np.zeros(8))


def test_counts_mask_zero_rows():
    d = jnp.full((8, 16), 9.0)
    x = jnp.zeros((8, 16))
    m = jnp.zeros((8, 16))  # fully padded
    got = window_scan.window_violation_counts(d, x, m)
    np.testing.assert_array_equal(np.asarray(got), np.zeros(8))


def test_counts_full_violation():
    d = jnp.ones((8, 32))
    x = jnp.zeros((8, 32))
    m = jnp.ones((8, 32))
    got = window_scan.window_violation_counts(d, x, m)
    np.testing.assert_array_equal(np.asarray(got), np.full(8, 32.0))


def test_counts_custom_block_size():
    rng = np.random.default_rng(7)
    d, x, m = _case(rng, 16, 24)
    a = window_scan.window_violation_counts(
        jnp.array(d), jnp.array(x), jnp.array(m), block_users=4
    )
    b = ref.window_violation_counts(jnp.array(d), jnp.array(x), jnp.array(m))
    np.testing.assert_allclose(np.asarray(a), np.asarray(b))


def test_counts_rejects_misaligned_batch():
    d = jnp.zeros((5, 8))
    with pytest.raises(AssertionError):
        window_scan.window_violation_counts(d, d, d)


# ----------------------------------------------------------------- sweep

@settings(max_examples=30, deadline=None)
@given(
    b_blocks=st.integers(1, 3),
    w=st.integers(1, 64),
    k=st.integers(1, 33),
    seed=st.integers(0, 2**31 - 1),
)
def test_sweep_matches_ref(b_blocks, w, k, seed):
    b = b_blocks * window_scan.DEFAULT_BLOCK_USERS
    rng = np.random.default_rng(seed)
    d, x, m = _case(rng, b, w)
    p = float(rng.random() * 0.3 + 1e-3)
    z = np.sort(rng.random(k) * 3).astype(np.float32)
    counts, dec = window_scan.threshold_sweep(
        jnp.array([p], jnp.float32), jnp.array(d), jnp.array(x), jnp.array(m), jnp.array(z)
    )
    counts_ref, dec_ref = ref.threshold_decisions(
        jnp.array(d), jnp.array(x), jnp.array(m), jnp.array(z), p
    )
    np.testing.assert_allclose(np.asarray(counts), np.asarray(counts_ref))
    np.testing.assert_array_equal(np.asarray(dec), np.asarray(dec_ref))


def test_sweep_threshold_boundary():
    # cost exactly equal to z must NOT trigger (strict >)
    d = jnp.ones((8, 10))
    x = jnp.zeros((8, 10))
    m = jnp.ones((8, 10))
    p = jnp.array([0.1], jnp.float32)
    z = jnp.array([1.0, 0.999999, 1.000001], jnp.float32)  # cost = 1.0
    _, dec = window_scan.threshold_sweep(p, d, x, m, z)
    dec = np.asarray(dec)
    np.testing.assert_array_equal(dec[:, 0], np.zeros(8))  # == -> no
    np.testing.assert_array_equal(dec[:, 1], np.ones(8))  # just below -> yes
    np.testing.assert_array_equal(dec[:, 2], np.zeros(8))  # above -> no


def test_sweep_decision_monotone_in_z():
    rng = np.random.default_rng(3)
    d, x, m = _case(rng, 8, 40)
    z = np.linspace(0, 4, 16).astype(np.float32)
    _, dec = window_scan.threshold_sweep(
        jnp.array([0.2], jnp.float32), jnp.array(d), jnp.array(x), jnp.array(m), jnp.array(z)
    )
    dec = np.asarray(dec)
    # rows must be non-increasing along the sorted z axis
    assert (np.diff(dec, axis=1) <= 0).all()


# ------------------------------------------------------------ vmem model

def test_vmem_estimate_production_tile_fits():
    # production artifact: BU=8 x W=8760 x K=64 tile must fit VMEM with
    # double buffering (2x inputs) under the ~16 MiB budget.
    est = window_scan.vmem_bytes(window_scan.DEFAULT_BLOCK_USERS, 8760, 64)
    assert 2 * est < 16 * 2**20, f"tile working set {est} bytes too large"
