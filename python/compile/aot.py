"""AOT lowering: JAX (L2, calling the L1 Pallas kernels) -> HLO text.

Run once at build time (``make artifacts``); the Rust runtime loads the
emitted ``artifacts/*.hlo.txt`` through the PJRT CPU client. Python is
never on the request path.

HLO **text** (not serialized HloModuleProto) is the interchange format:
jax >= 0.5 emits protos with 64-bit instruction ids which xla_extension
0.5.1 (the version behind the published ``xla`` crate) rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and round-trips
cleanly. Lowered with ``return_tuple=True``; the Rust side unwraps tuples.

Each artifact is shape-specialized. ``manifest.json`` records the catalog
(name, input shapes, outputs) so the Rust runtime can pick a variant and
pad batches accordingly.

Usage: ``python -m compile.aot --out-dir ../artifacts``
"""

from __future__ import annotations

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


# ----------------------------------------------------------------------
# Artifact catalog. Names encode the static shapes; the Rust runtime pads
# batches up to the chosen variant. One "full-scale" variant per graph
# (the coordinator's production tick), one mid variant, one small variant
# used by integration tests and the quickstart example.
# ----------------------------------------------------------------------

def catalog():
    entries = []

    def fleet_step_entry(b, w, k, block_users=None):
        name = f"fleet_step_b{b}_w{w}_k{k}"
        args = (f32(1), f32(b, w), f32(b, w), f32(b, w), f32(k))
        fn = functools.partial(model.fleet_step, block_users=block_users)
        entries.append(
            dict(
                name=name,
                kind="fleet_step",
                lower=lambda: jax.jit(fn).lower(*args),
                inputs=dict(p=[1], demand=[b, w], reserved=[b, w], mask=[b, w], z_grid=[k]),
                outputs=dict(counts=[b], decisions=[b, k]),
                params=dict(B=b, W=w, K=k),
            )
        )

    def ar_entry(b, l, k, h):
        name = f"ar_forecast_b{b}_l{l}_k{k}_h{h}"
        fn = functools.partial(model.ar_forecast, horizon=h)
        args = (f32(b, l), f32(b, k + 1))
        entries.append(
            dict(
                name=name,
                kind="ar_forecast",
                lower=lambda: jax.jit(fn).lower(*args),
                inputs=dict(history=[b, l], coef=[b, k + 1]),
                outputs=dict(forecast=[b, h]),
                params=dict(B=b, L=l, k=k, H=h),
            )
        )

    def cost_entry(b, w):
        name = f"cost_summary_b{b}_w{w}"
        args = (f32(1), f32(1), f32(b, w), f32(b, w), f32(b, w), f32(b, w))
        entries.append(
            dict(
                name=name,
                kind="cost_summary",
                lower=lambda: jax.jit(model.fleet_cost_summary).lower(*args),
                inputs=dict(
                    p=[1], alpha=[1], demand=[b, w], on_demand=[b, w],
                    reservations=[b, w], mask=[b, w],
                ),
                outputs=dict(summary=[b, 3]),
                params=dict(B=b, W=w),
            )
        )

    # production tick: 128 users x full compressed reservation period;
    # 32-user VMEM tiles (Perf L1-1: 4 grid steps instead of 16)
    fleet_step_entry(128, 8760, 64, block_users=32)
    # mid-size tick for smaller deployments / benches
    fleet_step_entry(32, 1024, 32)
    # small variant for tests + quickstart
    fleet_step_entry(8, 64, 8)

    ar_entry(128, 128, 4, 60)
    ar_entry(8, 32, 2, 8)

    cost_entry(128, 1024)
    cost_entry(8, 16)

    return entries


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None, help="emit a single artifact by name")
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    manifest_path_prev = os.path.join(args.out_dir, "manifest.json")
    # --only regenerates one artifact but must keep the full catalog in the
    # manifest; start from the previous manifest and replace entries.
    previous = {}
    if args.only and os.path.exists(manifest_path_prev):
        with open(manifest_path_prev) as f:
            previous = {e["name"]: e for e in json.load(f)}
    manifest = []
    for entry in catalog():
        meta = dict(
            name=entry["name"],
            kind=entry["kind"],
            file=entry["name"] + ".hlo.txt",
            inputs=entry["inputs"],
            outputs=entry["outputs"],
            params=entry["params"],
        )
        if args.only and entry["name"] != args.only:
            if entry["name"] in previous:
                manifest.append(previous[entry["name"]])
            continue
        path = os.path.join(args.out_dir, meta["file"])
        text = to_hlo_text(entry["lower"]())
        with open(path, "w") as f:
            f.write(text)
        manifest.append(meta)
        print(f"wrote {path} ({len(text)} chars)")

    manifest_path = os.path.join(args.out_dir, "manifest.json")
    with open(manifest_path, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {manifest_path} ({len(manifest)} artifacts)")


if __name__ == "__main__":
    main()
