"""Layer 1 -- Pallas kernels for the fleet-scale break-even window scan.

The compute hot-spot of the coordinator's analytics tick: for B users at
once, reduce the (demand > reservation-curve) indicator over a
reservation-period window, then (optionally) compare the resulting
violation cost against a grid of K thresholds (the A_z family).

TPU mapping (DESIGN.md "Hardware-Adaptation"): the scan is memory-bound;
we tile (BU, W) blocks of the demand and reservation matrices into VMEM
via BlockSpec so each row is streamed through HBM exactly once. The
indicator compare + masked reduction vectorizes on the VPU (8x128 lanes);
no MXU is involved. The threshold-sweep kernel broadcasts each user tile
against all K thresholds while it is VMEM-resident, turning K passes over
HBM into one.

The kernels MUST run ``interpret=True`` here: real-TPU lowering produces a
Mosaic custom-call the CPU PJRT plugin cannot execute. ``interpret=True``
lowers them to plain HLO, which compiles anywhere (and is what the AOT
artifacts ship).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Users per grid step. 8 sublanes x f32 works well on TPU; on the CPU
# interpret path it simply bounds working-set size.
DEFAULT_BLOCK_USERS = 8


def _count_kernel(d_ref, x_ref, m_ref, out_ref):
    """One (BU, W) tile: masked violation-count reduction along W."""
    d = d_ref[...]
    x = x_ref[...]
    m = m_ref[...]
    viol = jnp.where(d > x, 1.0, 0.0) * m
    out_ref[...] = viol.sum(axis=-1)


@functools.partial(jax.jit, static_argnames=("block_users",))
def window_violation_counts(demand, reserved, mask, *, block_users: int = DEFAULT_BLOCK_USERS):
    """Pallas version of :func:`ref.window_violation_counts`.

    Shapes: demand/reserved/mask f32[B, W] -> f32[B]. B must be a multiple
    of ``block_users`` (the AOT wrapper pads).
    """
    b, w = demand.shape
    assert b % block_users == 0, f"B={b} not a multiple of block_users={block_users}"
    grid = (b // block_users,)
    row_spec = pl.BlockSpec((block_users, w), lambda i: (i, 0))
    return pl.pallas_call(
        _count_kernel,
        grid=grid,
        in_specs=[row_spec, row_spec, row_spec],
        out_specs=pl.BlockSpec((block_users,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((b,), jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(demand, reserved, mask)


def _sweep_kernel(p_ref, d_ref, x_ref, m_ref, z_ref, cnt_ref, dec_ref):
    """One (BU, W) tile against all K thresholds while VMEM-resident."""
    d = d_ref[...]
    x = x_ref[...]
    m = m_ref[...]
    z = z_ref[...]  # (K,)
    p = p_ref[0]
    viol = jnp.where(d > x, 1.0, 0.0) * m
    counts = viol.sum(axis=-1)  # (BU,)
    cnt_ref[...] = counts
    cost = p * counts[:, None]  # (BU, 1)
    dec_ref[...] = jnp.where(cost > z[None, :], 1.0, 0.0)


@functools.partial(jax.jit, static_argnames=("block_users",))
def threshold_sweep(p, demand, reserved, mask, z_grid, *, block_users: int = DEFAULT_BLOCK_USERS):
    """Pallas version of :func:`ref.threshold_decisions`.

    Args:
      p: f32[1] normalized on-demand rate (runtime input, not baked in).
      demand/reserved/mask: f32[B, W].
      z_grid: f32[K].

    Returns: (counts f32[B], decisions f32[B, K]).
    """
    b, w = demand.shape
    (k,) = z_grid.shape
    assert b % block_users == 0
    grid = (b // block_users,)
    row_spec = pl.BlockSpec((block_users, w), lambda i: (i, 0))
    full_z = pl.BlockSpec((k,), lambda i: (0,))
    scalar = pl.BlockSpec((1,), lambda i: (0,))
    return pl.pallas_call(
        _sweep_kernel,
        grid=grid,
        in_specs=[scalar, row_spec, row_spec, row_spec, full_z],
        out_specs=[
            pl.BlockSpec((block_users,), lambda i: (i,)),
            pl.BlockSpec((block_users, k), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b,), jnp.float32),
            jax.ShapeDtypeStruct((b, k), jnp.float32),
        ],
        interpret=True,
    )(p, demand, reserved, mask, z_grid)


def vmem_bytes(block_users: int, window: int, k: int) -> int:
    """Estimated VMEM working set of one `_sweep_kernel` tile (f32).

    3 input tiles (d, x, m) + the z row + count/decision outputs; used by
    DESIGN.md/EXPERIMENTS.md Perf to check the tile fits the ~16 MB VMEM of
    a TPU core with double buffering.
    """
    tile = block_users * window * 4
    return 3 * tile + k * 4 + block_users * 4 + block_users * k * 4
