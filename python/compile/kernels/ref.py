"""Pure-jnp reference oracles for the Pallas kernels (Layer 1).

These are the ground truth the kernels are validated against (pytest +
hypothesis sweeps in ``python/tests/``). They implement the fleet-scale
break-even window scan of Algorithm 1/3:

* ``window_violation_counts`` -- for each user ``u``, the number of slots in
  its recent reservation-period window where demand exceeded the bookkeeping
  reservation curve: ``V_u = sum_i mask[u,i] * I(d[u,i] > x[u,i])``. The
  while-condition of Algorithm 1 is then ``p * V_u > z_u``.
* ``threshold_decisions`` -- the same counts compared against a *grid* of
  thresholds (the family A_z of Sec. V-A): out[u, k] = I(p*V_u > z[k]).
  The coordinator uses this to position every user against the whole
  aggressiveness spectrum in one pass (randomized-policy analytics).
* ``ar_forecast_ref`` -- iterated AR(k) multi-step forecast (Layer 2's
  prediction-window feeder, Sec. VI).
"""

from __future__ import annotations

import jax.numpy as jnp


def window_violation_counts(demand, reserved, mask):
    """Count masked slots where demand exceeds the reservation curve.

    Args:
      demand:   f32[B, W] demand window per user.
      reserved: f32[B, W] bookkeeping reservation curve (actual + phantom).
      mask:     f32[B, W] 1.0 for valid slots, 0.0 for padding.

    Returns:
      f32[B] violation counts.
    """
    viol = (demand > reserved).astype(jnp.float32) * mask
    return viol.sum(axis=-1)


def threshold_decisions(demand, reserved, mask, z_grid, p):
    """Compare the violation cost p*V_u against each threshold in a grid.

    Args:
      demand, reserved, mask: as in :func:`window_violation_counts`.
      z_grid: f32[K] thresholds (0 <= z <= beta).
      p: python float, normalized on-demand rate.

    Returns:
      (counts f32[B], decisions f32[B, K]) where
      decisions[u, k] = 1.0 iff p * counts[u] > z_grid[k].
    """
    counts = window_violation_counts(demand, reserved, mask)
    cost = p * counts[:, None]
    return counts, (cost > z_grid[None, :]).astype(jnp.float32)


def ar_forecast_ref(history, coef, horizon: int):
    """Iterated AR(k) forecast.

    Args:
      history: f32[B, L] recent demand per user (oldest first).
      coef:    f32[B, k+1] per-user AR coefficients [c, a_1, ..., a_k]
               (a_j multiplies the value j steps back).
      horizon: number of steps to forecast.

    Returns:
      f32[B, horizon] non-negative forecasts.
    """
    b, _ = history.shape
    k = coef.shape[1] - 1
    # maintain the last k values, newest last
    state = history[:, -k:] if k > 0 else jnp.zeros((b, 0), history.dtype)
    outs = []
    for _ in range(horizon):
        # y = c + sum_j a_j * state[:, -j]
        y = coef[:, 0]
        for j in range(1, k + 1):
            y = y + coef[:, j] * state[:, -j]
        y = jnp.maximum(y, 0.0)
        outs.append(y)
        if k > 0:
            state = jnp.concatenate([state[:, 1:], y[:, None]], axis=1)
    return jnp.stack(outs, axis=1)
