"""Layer 2 -- the JAX compute graphs AOT-compiled for the Rust coordinator.

Two graphs, both shipped as HLO text artifacts:

* ``fleet_step`` -- the coordinator's analytics tick: for a batch of users,
  run the L1 Pallas break-even window scan and position every user against
  a grid of A_z thresholds. Rust feeds per-user (demand window, bookkeeping
  reservation curve, mask) tensors and gets back violation counts and the
  z-grid decision matrix.
* ``ar_forecast`` -- batched iterated AR(k) demand forecast for the
  prediction-window policies (Sec. VI). Coefficients are fit in Rust
  (`forecast::fit_ar`) and applied here; the unrolled multiply-add chain
  fuses into a handful of HLO ops.

Shapes are static per artifact (PJRT executables are shape-specialized);
`aot.py` emits a small catalog of variants and the Rust runtime pads
batches to the nearest one.
"""

from __future__ import annotations

import jax.numpy as jnp

from .kernels import window_scan
from .kernels.ref import ar_forecast_ref


def fleet_step(p, demand, reserved, mask, z_grid, *, block_users=None):
    """Fleet analytics tick.

    Args:
      p:        f32[1] normalized on-demand rate.
      demand:   f32[B, W] per-user demand windows.
      reserved: f32[B, W] per-user bookkeeping reservation curves.
      mask:     f32[B, W] validity mask (ragged windows / padding).
      z_grid:   f32[K] thresholds spanning [0, beta].

    Returns:
      counts:    f32[B]   violation counts V_u.
      decisions: f32[B,K] I(p*V_u > z_k)  -- the A_z family's reserve
                 signals for every user x aggressiveness level.
    """
    kw = {} if block_users is None else dict(block_users=block_users)
    counts, decisions = window_scan.threshold_sweep(p, demand, reserved, mask, z_grid, **kw)
    return counts, decisions


def ar_forecast(history, coef, horizon: int):
    """Batched iterated AR(k) forecast (see ref.ar_forecast_ref).

    The reference implementation *is* the model here -- a short unrolled
    scan of fused multiply-adds; XLA folds it into a single fusion. Kept as
    a separate symbol so the artifact and tests pin its semantics.
    """
    return ar_forecast_ref(history, coef, horizon)


def fleet_cost_summary(p, alpha, demand, on_demand, reservations, mask):
    """Batched cost accounting (Eq. 1 summed over a horizon).

    Used by the coordinator's billing cross-check path: given per-slot
    demand, on-demand counts and new-reservation counts for B users over W
    slots, produce each user's cost decomposition

      total_u = sum_t r[u,t] + p*o[u,t] + alpha*p*(d[u,t]-o[u,t])

    Returns f32[B, 3]: (total, on_demand_cost, reservation_fees).
    """
    od_cost = (p * on_demand * mask).sum(axis=-1)
    fees = (reservations * mask).sum(axis=-1)
    reserved_use = ((demand - on_demand) * mask).sum(axis=-1)
    total = fees + od_cost + alpha * p * reserved_use
    return jnp.stack([total, od_cost, fees], axis=1)
